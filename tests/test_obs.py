"""Unified observability layer (src/repro/obs, docs/observability.md).

Covers: bounded-histogram percentile estimation and memory, registry
get-or-create semantics, exporter formats (JSON schema + Prometheus text
passing its own linter), span tracer nesting, run provenance, and — the
part that can silently rot — thread-safety: racing writers over one
registry, the sampled loader's real prefetch worker sharing a registry
with a consumer thread, and micro-batched serving with concurrent
submitters, all asserting exact (no-lost-update) counts.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       SpanTracer, exponential_bounds, lint_prometheus,
                       pow2_bounds, registry_to_json, run_context,
                       to_prometheus_text, write_metrics)


# ------------------------------------------------------------- primitives

def test_bounds_ladders():
    b = exponential_bounds(1e-6, 2.0, 31)
    assert len(b) == 31 and b[0] == 1e-6
    assert all(y == pytest.approx(2 * x) for x, y in zip(b, b[1:]))
    p = pow2_bounds(4096)
    assert p[0] == 1.0 and p[-1] == 4096.0
    assert all(y == 2 * x for x, y in zip(p, p[1:]))


def test_counter_monotonic():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_add():
    g = Gauge("g")
    g.set(4.0)
    g.add(-1.5)
    assert g.value == 2.5


def test_histogram_percentiles_interpolate():
    h = Histogram("h")
    rng = np.random.default_rng(0)
    xs = rng.uniform(1e-3, 1.0, size=10_000)
    for x in xs:
        h.observe(float(x))
    # factor-2 buckets + in-bucket interpolation: a few percent error on a
    # uniform distribution, far tighter than the 2x bucket-width bound
    for q in (50, 90, 99):
        true = float(np.percentile(xs, q))
        assert h.percentile(q) == pytest.approx(true, rel=0.25)
    assert h.count == 10_000
    assert h.percentile(0) >= float(xs.min())
    assert h.percentile(100) == pytest.approx(float(xs.max()))


def test_histogram_empty_and_memory_bounded():
    h = Histogram("h", bounds=(1.0, 2.0, 4.0))
    assert math.isnan(h.percentile(50))
    n_slots = len(h._counts)
    for i in range(50_000):
        h.observe(float(i % 7))
    assert len(h._counts) == n_slots          # fixed buckets, forever
    assert h.count == 50_000
    assert h.snapshot()["max"] == 6.0


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram("h", bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", bounds=(1.0, 1.0, 2.0))


# --------------------------------------------------------------- registry

def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    a = reg.counter("x_total", desc="first wins")
    b = reg.counter("x_total")
    assert a is b
    # distinct labels -> distinct metrics; lookup round-trips
    la = reg.counter("y_total", labels={"shard": 0})
    lb = reg.counter("y_total", labels={"shard": 1})
    assert la is not lb
    assert reg.get("y_total", labels={"shard": 1}) is lb
    assert reg.get("nope") is None


def test_registry_kind_and_bounds_mismatch_raise():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.histogram("m")
    reg.histogram("h", bounds=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(1.0, 4.0))


def test_registry_writer_race_exact_counts():
    reg = MetricsRegistry()
    c = reg.counter("raced_total")
    h = reg.histogram("raced_seconds")
    n_threads, n_iter = 8, 5_000

    def work():
        for i in range(n_iter):
            c.inc()
            h.observe(1e-3 * (i % 10 + 1))

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * n_iter
    assert h.count == n_threads * n_iter
    assert h.sum == pytest.approx(n_threads * sum(
        1e-3 * (i % 10 + 1) for i in range(n_iter)))


# ----------------------------------------------------------------- tracer

def test_tracer_nesting_and_records():
    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    with tr.span("outer"):
        with tr.span("inner", k=1) as sp:
            assert sp.sync("passthrough") == "passthrough"
    paths = [r["span"] for r in tr.records()]
    assert paths == ["outer/inner", "outer"]      # children close first
    h = reg.get("span_seconds", labels={"span": "outer/inner"})
    assert h is not None and h.count == 1
    assert tr.records()[0]["attrs"] == {"k": 1}


def test_tracer_ring_buffer_bounded():
    tr = SpanTracer(MetricsRegistry(), max_spans=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    recs = tr.records()
    assert len(recs) == 4 and recs[-1]["span"] == "s9"


def test_tracer_records_carry_thread_identity():
    tr = SpanTracer(MetricsRegistry())
    with tr.span("main_side"):
        pass

    def worker():
        with tr.span("thread_side"):
            pass

    t = threading.Thread(target=worker, name="worker-0")
    t.start()
    t.join()
    recs = {r["span"]: r for r in tr.records()}
    # compact per-tracer tids (Chrome-trace tracks), plus the thread name
    assert recs["main_side"]["tid"] == 0
    assert recs["thread_side"]["tid"] == 1
    assert recs["thread_side"]["thread"] == "worker-0"
    assert recs["main_side"]["thread"] == threading.current_thread().name


# -------------------------------------------------------------- exporters

def _toy_registry():
    reg = MetricsRegistry()
    reg.counter("reqs_total", desc="requests").inc(3)
    reg.gauge("depth", labels={"shard": 0}).set(7)
    h = reg.histogram("lat_seconds", desc="latency")
    for v in (0.001, 0.004, 0.2):
        h.observe(v)
    return reg


def test_json_export_schema(tmp_path):
    reg = _toy_registry()
    doc = registry_to_json(reg, context=run_context())
    doc2 = json.loads(json.dumps(doc))           # JSON-able end to end
    assert doc2["schema"] == "repro.obs/v1"
    by_name = {m["name"]: m for m in doc2["metrics"]}
    assert by_name["reqs_total"]["value"] == 3.0
    assert by_name["lat_seconds"]["count"] == 3
    assert {"p50", "p90", "p99"} <= set(by_name["lat_seconds"])
    p = tmp_path / "m.json"
    write_metrics(reg, str(p), "json")
    assert json.loads(p.read_text())["schema"] == "repro.obs/v1"
    with pytest.raises(ValueError):
        write_metrics(reg, str(p), "xml")


def test_prometheus_export_lints_clean():
    text = to_prometheus_text(_toy_registry())
    assert lint_prometheus(text) == []
    assert "# TYPE reqs_total counter" in text
    assert 'le="+Inf"' in text
    assert 'depth{shard="0"} 7' in text


def test_prometheus_lint_catches_malformed():
    # bucket counts not cumulative + _count disagreeing with +Inf
    bad = (
        '# TYPE x_seconds histogram\n'
        'x_seconds_bucket{le="0.1"} 5\n'
        'x_seconds_bucket{le="1"} 3\n'
        'x_seconds_bucket{le="+Inf"} 3\n'
        'x_seconds_sum 1.0\n'
        'x_seconds_count 9\n')
    assert lint_prometheus(bad) != []
    assert lint_prometheus("no_type_metric 1\n") != []


def test_label_value_escaping_round_trip():
    from repro.obs import unescape_label_value

    reg = MetricsRegistry()
    nasty = 'quote " back \\ newline \n done'
    reg.gauge("weird", labels={"k": nasty}).set(1)
    text = to_prometheus_text(reg)
    # raw specials never appear inside a label value on the wire ...
    assert lint_prometheus(text) == []
    assert "\n done" not in text.split("# TYPE", 1)[1].splitlines()[1]
    # ... and the escaped value round-trips exactly
    line = [l for l in text.splitlines() if l.startswith("weird{")][0]
    escaped = line[line.index('k="') + 3:line.rindex('"')]
    assert unescape_label_value(escaped) == nasty


def test_lint_rejects_unescaped_label_values():
    # raw backslash-quote corruption inside a label value
    bad = '# TYPE g gauge\ng{k="a"b"} 1\n'
    assert lint_prometheus(bad) != []
    # an unescaped lone backslash at value end
    bad2 = '# TYPE g gauge\ng{k="a\\"} 1\n'
    assert lint_prometheus(bad2) != []
    # properly escaped versions pass
    good = '# TYPE g gauge\ng{k="a\\"b"} 1\ng{k="a\\\\"} 2\n'
    assert lint_prometheus(good) == []


def test_run_context_fields():
    ctx = run_context()
    assert ctx["git_sha"] and ctx["timestamp"] and ctx["python"]
    assert run_context() == ctx                  # cached, stable


# --------------------------------------- cross-component thread-safety

def test_loader_prefetch_worker_shares_registry(small_graph):
    """The loader's real prefetch thread and a consumer 'train' thread
    both write one registry; every count must land exactly."""
    from repro.models.gnn import GNNConfig, structural_labels
    from repro.sampling import LoaderConfig, SampledLoader

    g = small_graph
    rng = np.random.default_rng(0)
    feat = rng.standard_normal((g.num_nodes, 6)).astype(np.float32)
    cfg = GNNConfig(arch="gcn", in_dim=6, hidden_dim=6, num_classes=3,
                    num_layers=2)
    labels = structural_labels(g, 3)
    reg = MetricsRegistry()
    steps = 6
    h_train = reg.histogram("train_step_seconds")

    with SampledLoader(g, feat, labels, cfg,
                       LoaderConfig(fanouts=(4, 2), batch_nodes=32, seed=0,
                                    tune_iters=2),
                       registry=reg) as loader:
        def train_thread():
            for s in range(steps):
                loader(s)                        # waits on prefetch worker
                h_train.observe(1e-4)

        t = threading.Thread(target=train_thread)
        t.start()
        t.join()

    assert h_train.count == steps
    # the worker prefetches ahead, so it may have built 1-2 batches the
    # consumer never took — but never fewer than were consumed
    built = reg.get("loader_batches_built_total")
    assert built is not None and steps <= built.value <= steps + 2
    stall = reg.get("loader_prefetch_stall_seconds")
    assert stall is not None and stall.count == steps
    st = loader.stats()
    assert st["batches_built"] == built.value


def test_engine_concurrent_submit_flush_no_lost_counts(rng):
    from repro.graphs.csr import random_power_law
    from repro.models.gnn import GNNConfig
    from repro.serving import ServingConfig, ServingEngine

    g = random_power_law(200, 4.0, seed=9)
    cfg = GNNConfig(arch="gcn", in_dim=4, hidden_dim=4, num_classes=3,
                    num_layers=2)
    feat = rng.standard_normal((g.num_nodes, 4)).astype(np.float32)
    reg = MetricsRegistry()
    eng = ServingEngine(g, feat, cfg, registry=reg,
                        serving=ServingConfig(max_batch=4, tune_iters=2))
    n_threads, per_thread = 4, 8
    seeds = rng.integers(0, g.num_nodes, size=n_threads * per_thread)

    def submit(block):
        for s in block:
            eng.submit(int(s))

    ts = [threading.Thread(target=submit,
                           args=(seeds[i * per_thread:(i + 1) * per_thread],))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    while eng.batcher.pending():
        eng.step(force=True)

    total = n_threads * per_thread
    s = eng.summary()
    assert s["requests"] == total
    assert reg.get("serve_requests_total").value == total
    assert reg.get("serve_request_latency_seconds").count == total
    assert reg.get("serve_queue_wait_seconds").count == total
    # summary keys stay backward-compatible with the pre-registry engine
    assert {"requests", "batches", "req_per_s", "p50_ms", "p99_ms",
            "batch_occupancy", "avg_sub_nodes", "cache"} <= set(s)
    # concurrent snapshot while serving more traffic must not corrupt
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            json.dumps(registry_to_json(reg))

    r = threading.Thread(target=reader)
    r.start()
    try:
        eng.run_trace([int(x) for x in seeds[:8]])
    finally:
        stop.set()
        r.join()
    assert eng.summary()["requests"] == total + 8
