"""§6.1 renumbering + §7 advisor loop tests."""
import numpy as np
import pytest

from repro.core.advisor import advise
from repro.core.aggregate import PlanExecutor
from repro.core.extractor import extract_graph_props
from repro.core.model import AggConfig, KernelModel, config_is_feasible, paper_eq2_latency
from repro.core.partition import partition_graph, partition_stats
from repro.core.reorder import renumber
from repro.core.tuner import community_profile, evolve, tune
from repro.graphs.csr import random_community_graph, random_power_law


def test_renumber_is_permutation(community_graph):
    perm = renumber(community_graph, seed=0)
    n = community_graph.num_nodes
    assert sorted(perm.tolist()) == list(range(n))


def test_renumber_improves_locality():
    """Scrambled community graph: renumbering must reduce tile count
    (fewer feature-window DMAs — the Fig. 12b analogue)."""
    g = random_community_graph(16, 24, p_intra=0.5,
                               p_inter_edges_per_node=0.2, seed=5)
    # scramble the natural (already-local) ordering first
    rng = np.random.default_rng(0)
    scramble = rng.permutation(g.num_nodes)
    g_bad = g.permute(scramble)
    tiles_bad = partition_stats(partition_graph(g_bad, gs=8, gpt=8, ont=8,
                                                src_win=64))["tiles"]
    perm = renumber(g_bad, seed=0)
    g_fix = g_bad.permute(perm)
    tiles_fix = partition_stats(partition_graph(g_fix, gs=8, gpt=8, ont=8,
                                                src_win=64))["tiles"]
    assert tiles_fix < tiles_bad, (tiles_fix, tiles_bad)


def test_permute_preserves_edges(community_graph):
    g = community_graph
    perm = renumber(g, seed=1)
    g2 = g.permute(perm)
    e1 = set()
    for v in range(g.num_nodes):
        for u in g.neighbors(v):
            e1.add((perm[v], perm[u]))
    e2 = set()
    for v in range(g2.num_nodes):
        for u in g2.neighbors(v):
            e2.add((v, int(u)))
    assert e1 == e2


def test_extractor_props(small_graph):
    props = extract_graph_props(small_graph)
    assert props.num_nodes == small_graph.num_nodes
    assert props.num_edges == small_graph.num_edges
    assert props.max_degree >= props.avg_degree
    assert 0.15 <= props.alpha <= 0.3


def test_paper_eq2_shape_of_surface(small_graph):
    """Eq. 2 sanity: finite/positive everywhere; the (1 + |gs - pivot|)
    penalty grows when gs moves away from the pivot at fixed 1/gs factor."""
    props = extract_graph_props(small_graph, detect_communities=False)
    vals = [paper_eq2_latency(props, 64, AggConfig(gs=gs, gpt=g, dt=d))
            for gs in (4, 16, 64) for g in (8, 32) for d in (64, 256)]
    assert all(np.isfinite(v) and v > 0 for v in vals)
    # penalty factor isolated: same gs denominator, larger |gs - pivot|
    pivot = props.alpha * props.num_nodes / props.num_edges
    lat = lambda gs: paper_eq2_latency(props, 64, AggConfig(gs=gs)) * gs
    assert lat(64) >= lat(max(int(round(pivot)), 1))


def test_feasibility_constraints():
    assert config_is_feasible(AggConfig(gs=16, gpt=16, dt=128, src_win=512))
    # VMEM blow-up must be rejected (Eq. 4 analogue)
    assert not config_is_feasible(AggConfig(gs=16, gpt=128, dt=512,
                                            src_win=8192))


def test_tuner_monotone_and_feasible(small_graph):
    res = tune(small_graph, 64, mode="model", iters=8, seed=0)
    scores = [s for _, s in res.history]
    assert scores[-1] <= scores[0]
    assert config_is_feasible(res.best)
    assert res.evaluations > 0


def test_tuner_profile_mode(community_graph):
    res = tune(community_graph, 32, mode="profile", iters=4, pop=8, seed=0)
    assert config_is_feasible(res.best)


def test_community_profile_scorer():
    score = community_profile([16, 32], dim=32, seed=0)
    a = score(AggConfig(gs=8, gpt=16, dt=64, src_win=128))
    b = score(AggConfig(gs=64, gpt=128, dt=512, src_win=2048))
    assert a > 0 and b > 0 and np.isfinite([a, b]).all()


def test_advisor_end_to_end(community_graph, rng):
    import jax.numpy as jnp
    from repro.kernels import ref
    plan = advise(community_graph, arch="gcn", in_dim=32, hidden_dim=16,
                  tune_iters=3)
    ex = PlanExecutor(plan, backend="xla")
    feat = rng.standard_normal((community_graph.num_nodes, 32)).astype(np.float32)
    out = ex.aggregate_original_order(jnp.asarray(feat))
    rows, cols = community_graph.to_coo()
    want = ref.segment_aggregate_ref(
        jnp.asarray(feat), jnp.asarray(cols), jnp.asarray(rows),
        jnp.ones(community_graph.num_edges), community_graph.num_nodes)
    np.testing.assert_allclose(out, want, atol=1e-3)


def test_advisor_skips_reorder_for_local_graphs():
    """Type-II graphs arrive pre-localized — reorder='auto' must skip."""
    g = random_community_graph(20, 16, p_intra=0.6,
                               p_inter_edges_per_node=0.0, seed=7)
    plan = advise(g, arch="gcn", in_dim=8, hidden_dim=8, reorder="auto",
                  tune_iters=2)
    assert plan.perm is None
