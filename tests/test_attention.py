"""Attention substrate vs naive oracles: flash fwd/bwd, SWA, softcap,
triangle mode, GQA decode, M-RoPE, ring-buffer caches."""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.nn.attention import (AttnParams, attention_decode, attention_forward,
                                attention_init, blockwise_attention, init_cache,
                                m_rope, rope)
from repro.nn.flash import flash_attention
from repro.nn.layers import Initializer


def _naive(q, k, v, qpos, kpos, scale, softcap=None, window=None):
    H, K = q.shape[2], k.shape[2]
    k = jnp.repeat(k, H // K, axis=2)
    v = jnp.repeat(v, H // K, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = kpos[None, None, None, :] <= qpos[None, None, :, None]
    if window is not None:
        mask &= kpos[None, None, None, :] > (qpos[None, None, :, None] - window)
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", jnp.where(mask, p, 0.0),
                      v.astype(jnp.float32))


@pytest.fixture(scope="module")
def qkv():
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, hd))
    return q, k, v


@pytest.mark.parametrize("mode", ["flash", "masked_full", "triangle"])
@pytest.mark.parametrize("softcap", [None, 12.0])
def test_causal_modes_match_naive(qkv, mode, softcap):
    q, k, v = qkv
    S = q.shape[1]
    pos = jnp.arange(S)
    scale = 1 / math.sqrt(q.shape[-1])
    got = blockwise_attention(q, k, v, q_pos=pos, kv_pos=pos, softcap=softcap,
                              scale=scale, q_chunk=16, kv_chunk=16,
                              causal_mode=mode)
    want = _naive(q, k, v, pos, pos, scale, softcap)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("window", [8, 24])
def test_swa_matches_naive(qkv, window):
    q, k, v = qkv
    S = q.shape[1]
    pos = jnp.arange(S)
    scale = 1 / math.sqrt(q.shape[-1])
    got = blockwise_attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                              scale=scale, q_chunk=16, kv_chunk=16)
    want = _naive(q, k, v, pos, pos, scale, window=window)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_flash_gradients_match_naive(qkv):
    q, k, v = qkv
    S = q.shape[1]
    pos = jnp.arange(S)
    scale = 1 / math.sqrt(q.shape[-1])
    gout = jax.random.normal(jax.random.PRNGKey(3), q.shape)

    def f(q, k, v):
        o = blockwise_attention(q, k, v, q_pos=pos, kv_pos=pos, softcap=9.0,
                                window=20, scale=scale, q_chunk=16,
                                kv_chunk=16, causal_mode="flash")
        return (o * gout).sum()

    def n(q, k, v):
        return (_naive(q, k, v, pos, pos, scale, 9.0, 20) * gout).sum()

    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(a, b, atol=2e-3)


def test_decode_matches_prefill():
    cfg_d = 32
    ap = AttnParams(n_heads=4, n_kv=2, head_dim=8, softcap=20.0)
    p, _ = attention_init(Initializer(jax.random.PRNGKey(0),
                                      dtype=jnp.float32), cfg_d, ap)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg_d))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    y_pre = attention_forward(p, ap, x, pos, q_chunk=8, kv_chunk=8)
    cache = init_cache(2, ap, 24, dtype=jnp.float32)
    outs = []
    for t in range(24):
        yt, cache = attention_decode(p, ap, x[:, t:t + 1], cache,
                                     jnp.int32(t),
                                     jnp.broadcast_to(jnp.int32(t), (2, 1)))
        outs.append(yt)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y_pre, atol=1e-4)


def test_swa_ring_buffer_decode():
    """Ring-buffer cache (width W) must equal a full cache with window W."""
    d = 16
    ap_ring = AttnParams(n_heads=2, n_kv=2, head_dim=8, window=6)
    p, _ = attention_init(Initializer(jax.random.PRNGKey(0),
                                      dtype=jnp.float32), d, ap_ring)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, d))
    cache = init_cache(1, ap_ring, 20, dtype=jnp.float32)
    assert cache["k"].shape[1] == 6          # ring buffer is window-sized
    outs = []
    for t in range(20):
        yt, cache = attention_decode(p, ap_ring, x[:, t:t + 1], cache,
                                     jnp.int32(t),
                                     jnp.broadcast_to(jnp.int32(t), (1, 1)))
        outs.append(yt)
    got = jnp.concatenate(outs, 1)
    pos = jnp.broadcast_to(jnp.arange(20), (1, 20))
    want = attention_forward(p, ap_ring, x, pos, q_chunk=4, kv_chunk=4)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_rope_properties():
    """RoPE preserves norms and is relative: scores depend on pos deltas."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # relative property: shifting all positions leaves q.k dot products alike
    y2 = rope(x, pos + 17)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", y, y)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", y2, y2)
    np.testing.assert_allclose(s1, s2, atol=1e-3)


def test_mrope_sections():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos3 = jnp.broadcast_to(jnp.arange(8), (1, 3, 8))
    y = m_rope(x, pos3, (2, 3, 3))
    assert y.shape == x.shape
    # identical t/h/w position streams == plain rope
    y1 = rope(x, pos3[:, 0])
    np.testing.assert_allclose(y, y1, atol=1e-4)
