"""Async SLO-aware serving tier (docs/serving.md).

Four families of guarantees:

* **concurrency** — exact request accounting under multi-threaded
  submission (``submitted == completed + rejected``, nothing left
  pending), shutdown mid-flight without deadlock, deterministic
  queue-full rejection, EDF tenant isolation;
* **batcher invariants** — property-based (via the `_hypothesis_compat`
  shim, so they run with or without real hypothesis): the deadline
  batcher's planned close time never exceeds any admitted request's
  deadline, pops never exceed the size cap, FIFO order is preserved;
* **load generation** — `build_schedule` is a pure function of its spec
  (same seed ⇒ identical trace), which is what makes benchmark replays
  attributable;
* **integration** — the async tier returns the same logits as the
  synchronous engine path, tenants share one `PlanCache`, and the
  ``BENCH_serve.json`` document contract holds.
"""
import importlib.util
import math
import os
import sys
import threading
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.serving import (AsyncServingEngine, ClockBatcher, DeadlineBatcher,
                           LoadSpec, PlanCache, SLOClass, TenantSpec,
                           build_schedule, run_schedule, slo_classes,
                           zipf_seeds)
from repro.serving.admission import AdmissionQueue, AsyncRequest


def _req(rid, t_submit, deadline, tenant="t"):
    return AsyncRequest(rid=rid, tenant=tenant, seed=rid, t_submit=t_submit,
                        deadline=deadline)


def _echo_fn(delay=0.0):
    """serve_fn stub: returns each seed as a 1-wide logit row."""
    def fn(seeds):
        if delay:
            time.sleep(delay)
        return np.asarray(list(seeds), np.float32).reshape(-1, 1)
    return fn


# ------------------------------------------------------- admission / SLO

def test_slo_classes_tiering():
    gold, silver, bronze = slo_classes(0.1)
    assert (gold.slo_s, silver.slo_s, bronze.slo_s) == (0.1, 0.2, 0.4)
    with pytest.raises(ValueError):
        SLOClass("bad", 0.0)


def test_admission_queue_rejects_in_order():
    q = AdmissionQueue("t", capacity=2, slo=SLOClass("gold", 0.1))
    r = _req(0, 0.0, 0.1)
    assert q.admit(r, depth=0, closed=True, now=0.0) == "closed"
    assert r.status == "rejected" and r.reject_reason == "closed"
    r2 = _req(1, 0.0, 0.1)
    assert q.admit(r2, depth=2, closed=False, now=0.0) == "queue_full"
    r3 = _req(2, 0.0, 0.1)
    assert q.admit(r3, depth=1, closed=False, now=0.0) is None
    assert r3.status == "pending"


# ------------------------------------------------- batcher property tests

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 24), slo_ms=st.floats(1.0, 500.0),
       est_ms=st.floats(0.0, 50.0), margin_ms=st.floats(0.0, 10.0),
       seed=st.integers(0, 10_000))
def test_prop_deadline_close_respects_every_deadline(n, slo_ms, est_ms,
                                                     margin_ms, seed):
    """close_at + est + margin <= min(deadline over queued) — the batch is
    never PLANNED to finish past any admitted request's budget."""
    rng = np.random.default_rng(seed)
    b = DeadlineBatcher(max_batch=1024, est_fn=lambda: est_ms / 1e3,
                        margin=margin_ms / 1e3, idle_gap=None)
    t = 0.0
    for i in range(n):
        t += float(rng.uniform(0.0, 0.01))
        b.put(_req(i, t, t + slo_ms / 1e3 * float(rng.uniform(0.5, 1.5))),
              now=t)
    close = b.close_at(t)
    assert close + est_ms / 1e3 + margin_ms / 1e3 <= b.oldest_deadline() + 1e-12


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 24), gap_ms=st.floats(0.1, 50.0),
       seed=st.integers(0, 10_000))
def test_prop_idle_gap_bounds_close(n, gap_ms, seed):
    rng = np.random.default_rng(seed)
    b = DeadlineBatcher(max_batch=1024, margin=0.0, idle_gap=gap_ms / 1e3)
    t = 0.0
    for i in range(n):
        t += float(rng.uniform(0.0, 0.01))
        b.put(_req(i, t, t + 10.0), now=t)
    assert b.close_at(t) <= t + gap_ms / 1e3 + 1e-12
    assert b.close_at(t) <= b.oldest_deadline() + 1e-12


@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 100), max_batch=st.sampled_from([1, 2, 4, 8, 16, 32]),
       policy=st.booleans())
def test_prop_pop_caps_size_and_keeps_fifo(n, max_batch, policy):
    b = (DeadlineBatcher(max_batch=max_batch)
         if policy else ClockBatcher(max_batch=max_batch, window=0.01))
    for i in range(n):
        b.put(_req(i, float(i), float(i) + 1.0), now=float(i))
    popped = []
    while b.pending():
        batch = b.pop(float(n))
        assert 1 <= len(batch) <= max_batch
        popped.extend(r.rid for r in batch)
    assert popped == list(range(n))
    assert b.pop(float(n)) == [] and not b.due(float(n))


@settings(max_examples=25, deadline=None)
@given(window_ms=st.floats(0.0, 200.0), dt_ms=st.floats(0.0, 400.0),
       seed=st.integers(0, 10_000))
def test_prop_clock_window_anchors_on_batch_open(window_ms, dt_ms, seed):
    rng = np.random.default_rng(seed)
    t0 = float(rng.uniform(0.0, 5.0))
    b = ClockBatcher(max_batch=64, window=window_ms / 1e3)
    b.put(_req(0, t0, t0 + 1.0), now=t0)
    b.put(_req(1, t0 + 0.001, t0 + 1.0), now=t0 + 0.001)
    assert b.close_at(t0) == t0 + window_ms / 1e3
    # compare in the batcher's own units: ms-level comparison can disagree
    # with the float(now) pipeline by an ulp at the boundary
    now = t0 + dt_ms / 1e3
    assert b.due(now) == (now >= t0 + window_ms / 1e3)


def test_deadline_estimate_clamps_garbage():
    for bad in (math.nan, math.inf, -1.0):
        b = DeadlineBatcher(max_batch=4, est_fn=lambda v=bad: v)
        assert b.estimate() == 0.0
    b = DeadlineBatcher(max_batch=4, est_fn=lambda: 0.25)
    assert b.estimate() == 0.25


# ----------------------------------------------------- concurrency stress

def test_stress_exact_accounting_across_threads():
    """8 submitter threads x 3 tenants; every request terminal after
    drain, accounting exact, every result row equals its seed."""
    eng = AsyncServingEngine(
        [TenantSpec(f"t{i}", _echo_fn(0.0005), slo=SLOClass("gold", 2.0),
                    max_batch=16) for i in range(3)],
        idle_gap=0.002)
    per_thread, threads, all_reqs = 40, 8, []
    lock = threading.Lock()

    def submitter(k):
        rs = [eng.submit(k * per_thread + j, tenant=f"t{(k + j) % 3}")
              for j in range(per_thread)]
        with lock:
            all_reqs.extend(rs)

    ts = [threading.Thread(target=submitter, args=(k,)) for k in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert eng.drain(timeout=30.0)
    acc = eng.accounting()
    assert acc["submitted"] == threads * per_thread
    assert acc["submitted"] == acc["completed"] + acc["rejected"]
    assert acc["outstanding"] == 0
    assert all(r.terminal for r in all_reqs)
    for r in all_reqs:
        if r.status == "done":
            assert float(r.result[0]) == float(r.seed)
    assert eng.close()


def test_shutdown_mid_flight_never_deadlocks_or_drops():
    """close(drain=False) while batches are in flight: returns promptly,
    and every admitted request still reaches a terminal state."""
    eng = AsyncServingEngine(
        [TenantSpec("t", _echo_fn(0.01), slo=SLOClass("gold", 5.0),
                    max_batch=4)])
    reqs = [eng.submit(i) for i in range(60)]
    time.sleep(0.02)                      # let a few batches fire
    t0 = time.perf_counter()
    eng.close(drain=False, timeout=5.0)
    assert time.perf_counter() - t0 < 5.0
    for r in reqs:                        # in-flight batch may land late
        assert r.wait(2.0), f"request {r.rid} never became terminal"
    acc = eng.accounting()
    assert acc["submitted"] == acc["completed"] + acc["rejected"] == 60
    assert {r.status for r in reqs} <= {"done", "rejected"}
    assert all(r.reject_reason == "shutdown" for r in reqs
               if r.status == "rejected")


def test_close_drain_completes_everything():
    eng = AsyncServingEngine(
        [TenantSpec("t", _echo_fn(0.001), max_batch=8)], idle_gap=0.002)
    reqs = [eng.submit(i) for i in range(30)]
    assert eng.close(drain=True, timeout=30.0)
    assert all(r.status == "done" for r in reqs)


def test_close_timeout_rejects_queued():
    """A wedged serve_fn cannot wedge close(): the timeout fires, queued
    requests are rejected with reason="shutdown", close returns False."""
    eng = AsyncServingEngine(
        [TenantSpec("t", _echo_fn(0.5), max_batch=1)])
    reqs = [eng.submit(i) for i in range(5)]
    assert eng.close(drain=True, timeout=0.1) is False
    for r in reqs:
        assert r.wait(3.0)
    assert sum(r.status == "rejected" for r in reqs) >= 3
    acc = eng.accounting()
    assert acc["submitted"] == acc["completed"] + acc["rejected"] == 5


def test_submit_after_close_is_terminal_rejection():
    eng = AsyncServingEngine([TenantSpec("t", _echo_fn())])
    assert eng.close()
    r = eng.submit(0)
    assert r.terminal and r.status == "rejected" and r.reject_reason == "closed"
    assert eng.close()                    # idempotent


def test_queue_full_rejection_is_deterministic():
    """start=False: no worker consuming, so overflow counts are exact."""
    eng = AsyncServingEngine(
        [TenantSpec("t", _echo_fn(), queue_cap=4)], start=False)
    reqs = [eng.submit(i) for i in range(10)]
    rejected = [r for r in reqs if r.status == "rejected"]
    assert len(rejected) == 6
    assert all(r.reject_reason == "queue_full" for r in rejected)
    assert eng.close()                    # rejects the 4 queued: shutdown
    assert all(r.terminal for r in reqs)
    acc = eng.accounting()
    assert acc == {"submitted": 10, "completed": 0, "rejected": 10,
                   "outstanding": 0}


def test_edf_gold_tenant_overtakes_bronze_flood():
    """Per-tenant isolation: a bronze tenant flooding its queue delays a
    gold request by at most ~one in-flight batch — EDF fires the earlier
    deadline first, so the gold request finishes while most of the flood
    is still queued."""
    eng = AsyncServingEngine(
        [TenantSpec("gold", _echo_fn(0.005), slo=SLOClass("gold", 0.05),
                    max_batch=4),
         TenantSpec("bronze", _echo_fn(0.005), slo=SLOClass("bronze", 30.0),
                    max_batch=2)],
        idle_gap=0.002)
    flood = [eng.submit(i, tenant="bronze") for i in range(30)]
    g = eng.submit(999, tenant="gold")
    assert g.wait(5.0) and g.status == "done"
    done_before_gold = sum(1 for r in flood
                           if r.terminal and r.t_done <= g.t_done)
    assert done_before_gold <= len(flood) // 2, \
        f"gold waited behind {done_before_gold} flood requests"
    assert eng.drain(timeout=30.0)
    assert eng.close()


# ----------------------------------------------------------- load generator

def test_build_schedule_is_deterministic():
    spec = LoadSpec(requests=64, rate_rps=1000.0, tenants=("a", "b"), seed=3)
    s1, s2 = build_schedule(500, spec), build_schedule(500, spec)
    assert s1 == s2
    s3 = build_schedule(500, LoadSpec(requests=64, rate_rps=1000.0,
                                      tenants=("a", "b"), seed=4))
    assert s1 != s3
    assert all(a.tenant in ("a", "b") and 0 <= a.seed < 500 for a in s1)


def test_build_schedule_arrival_processes():
    burst = build_schedule(100, LoadSpec(requests=16, rate_rps=math.inf))
    assert all(a.t == 0.0 for a in burst)
    uni = build_schedule(100, LoadSpec(requests=16, rate_rps=100.0))
    np.testing.assert_allclose([a.t for a in uni], np.arange(16) / 100.0)
    poi = build_schedule(100, LoadSpec(requests=16, rate_rps=100.0,
                                       arrival="poisson", seed=5))
    ts = [a.t for a in poi]
    assert ts == sorted(ts) and ts[0] > 0.0
    with pytest.raises(ValueError):
        LoadSpec(requests=0)
    with pytest.raises(ValueError):
        LoadSpec(arrival="bursty")


def test_zipf_seeds_deterministic_hot_set():
    a = zipf_seeds(1000, 200, seed=7)
    b = zipf_seeds(1000, 200, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 1000
    assert len(np.unique(a)) <= max(1, int(1000 * 0.05))


def test_run_schedule_replay_accounts_exactly():
    eng = AsyncServingEngine([TenantSpec("a", _echo_fn()),
                              TenantSpec("b", _echo_fn())], idle_gap=0.002)
    sched = build_schedule(100, LoadSpec(requests=40, rate_rps=4000.0,
                                         tenants=("a", "b"), seed=1))
    res = run_schedule(eng, sched, drain_timeout=30.0)
    assert res["drained"] and res["completed"] == res["requests"] == 40
    assert res["throughput_rps"] > 0
    assert [r.seed for r in res["requests_detail"]] == [a.seed for a in sched]
    assert eng.close()


# --------------------------------------------------- bench document schema

def _load_validator():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "validate_metrics.py")
    spec = importlib.util.spec_from_file_location("validate_metrics", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_serve_document_schema(tmp_path):
    from benchmarks.bench_serve import CONFIG_KEYS, SCHEMA, _comparison
    vm = _load_validator()
    cell = {k: 1.0 for k in CONFIG_KEYS}
    good = {"schema": SCHEMA, "smoke": True,
            "context": {"git_sha": "abc123"},
            "configs": [dict(cell, shards=1, policy="deadline",
                             slo_attainment=1.0, throughput_rps=200.0),
                        dict(cell, shards=1, policy="clock",
                             throughput_rps=100.0),
                        dict(cell, shards=2, policy="deadline")],
            "comparison": _comparison([
                dict(cell, shards=1, policy="deadline", slo_attainment=1.0,
                     throughput_rps=200.0),
                dict(cell, shards=1, policy="clock", throughput_rps=100.0)])}
    assert good["comparison"]["pass"] is True
    p = tmp_path / "BENCH_serve.json"
    import json
    p.write_text(json.dumps(good))
    assert vm.validate_bench_serve(str(p)) == []
    assert vm.main([str(p)]) == 0

    bad = dict(good, schema="bogus", configs=[{"policy": "deadline"}])
    bad.pop("comparison")
    p2 = tmp_path / "BENCH_serve_bad.json"
    p2.write_text(json.dumps(bad))
    problems = "\n".join(vm.validate_bench_serve(str(p2)))
    assert "schema" in problems and "comparison" in problems
    assert "missing" in problems


def test_comparison_requires_attainment_and_throughput_win():
    from benchmarks.bench_serve import CONFIG_KEYS, _comparison
    cell = {k: 1.0 for k in CONFIG_KEYS}
    lose_attain = _comparison([
        dict(cell, shards=1, policy="deadline", slo_attainment=0.9,
             throughput_rps=200.0),
        dict(cell, shards=1, policy="clock", throughput_rps=100.0)])
    lose_tput = _comparison([
        dict(cell, shards=1, policy="deadline", slo_attainment=1.0,
             throughput_rps=90.0),
        dict(cell, shards=1, policy="clock", throughput_rps=100.0)])
    assert not lose_attain["pass"] and not lose_tput["pass"]
    assert not _comparison([])["pass"]


# ------------------------------------------------------------- integration

@pytest.fixture(scope="module")
def sync_engine(small_graph):
    from repro.models.gnn import GNNConfig
    from repro.serving import ServingConfig, ServingEngine
    cfg = GNNConfig(arch="gcn", in_dim=8, hidden_dim=8, num_classes=3,
                    num_layers=2, backend="xla")
    rng = np.random.default_rng(0)
    feat = rng.standard_normal((small_graph.num_nodes, 8)).astype(np.float32)
    return ServingEngine(small_graph, feat, cfg,
                         serving=ServingConfig(max_batch=8, tune_iters=2))


def test_async_engine_matches_sync_serving_path(sync_engine, small_graph):
    """Batched-through-the-async-tier logits agree with direct
    single-request inference (same tolerance contract as serve_gnn
    --verify: union-ego padding may reorder f32 accumulation)."""
    eng = AsyncServingEngine(
        [TenantSpec("m", sync_engine.serve_batch, max_batch=8)],
        idle_gap=0.005)
    rng = np.random.default_rng(1)
    seeds = rng.integers(0, small_graph.num_nodes, size=12)
    reqs = [eng.submit(int(s)) for s in seeds]
    assert eng.drain(timeout=120.0)
    assert eng.close()
    for r in reqs:
        assert r.status == "done"
        single = sync_engine.serve_batch([r.seed])[0]
        err = (np.abs(single - r.result) / (1.0 + np.abs(single))).max()
        assert err <= 1e-5, (r.seed, err)


def test_tenants_share_plan_cache(sync_engine, small_graph):
    """Multi-tenant routing over ONE fingerprint-keyed PlanCache: a second
    tenant engine (same graph/arch, its own weights) replays the first
    tenant's plans as exact hits instead of re-planning."""
    import jax
    from repro.serving import ServingConfig, ServingEngine
    cache = sync_engine.cache
    eng2 = ServingEngine(small_graph, sync_engine.feat, sync_engine.cfg,
                         key=jax.random.PRNGKey(42),
                         serving=ServingConfig(max_batch=8, tune_iters=2),
                         cache=cache)
    seeds = [3, 77]
    sync_engine.serve_batch(seeds)
    before = cache.stats()["exact_hits"]
    eng2.serve_batch(seeds)
    assert cache.stats()["exact_hits"] > before


def test_shared_cache_policy_mismatch_raises(sync_engine, small_graph):
    import dataclasses
    from repro.serving import ServingEngine
    cfg16 = dataclasses.replace(sync_engine.cfg, feat_dtype="bfloat16")
    with pytest.raises(ValueError, match="mismatch"):
        ServingEngine(small_graph, sync_engine.feat, cfg16,
                      cache=sync_engine.cache)
