import os
import sys

# tests must see exactly ONE device (the dry-run forces 512 in its own
# process); make sure no ambient XLA_FLAGS leaks in.
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graph():
    from repro.graphs.csr import random_power_law
    return random_power_law(300, 6.0, seed=1)


@pytest.fixture(scope="session")
def community_graph():
    from repro.graphs.csr import random_community_graph
    return random_community_graph(12, 20, p_intra=0.4,
                                  p_inter_edges_per_node=0.3, seed=2)
