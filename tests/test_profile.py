"""Profiling harness, Chrome-trace export, perf baselines + CI gate.

Covers the observability tentpole: `measure` calibration and stats,
`profile_plan` per-schedule attribution (sum-to-total identity) and
registry side-effects, the Chrome/Perfetto exporter's event structure,
`repro.obs.baseline` verdicts, and the `tools/bench_compare.py` CLI
(clean / regressed / missing-row / schema-mismatch exits).
"""
from __future__ import annotations

import importlib.util
import json
import os
import time

import numpy as np
import pytest

from repro.obs import (MetricsRegistry, SpanTracer, chrome_trace_doc,
                       compare_rows, make_baseline, measure, profile_plan,
                       row_tolerance, save_baseline, validate_baseline,
                       write_chrome_trace)
from repro.obs.profile import Measurement


def _load_bench_compare():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "bench_compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------- measure

def test_measure_basic_stats():
    m = measure(lambda x: x + 1, 2.0, warmup=1, iters=6)
    assert m.count == 6 and m.warmup == 1
    assert m.min <= m.p50 <= m.p90 <= m.max
    row = m.to_row()
    assert set(row) == {"p50_us", "p90_us", "min_us", "mean_us", "iters"}
    assert row["iters"] == 6


def test_measure_quantiles_match_numpy():
    samples = (0.5, 0.1, 0.9, 0.3, 0.7, 0.2)
    m = Measurement(samples=samples, warmup=0)
    assert m.p50 == pytest.approx(np.median(samples))
    assert m.p90 == pytest.approx(np.quantile(samples, 0.9))
    assert m.min == min(samples)


def test_measure_trimmed_mean_drops_outliers():
    # one huge outlier among ten samples must not move the trimmed mean
    samples = (1.0,) * 9 + (100.0,)
    m = Measurement(samples=samples, warmup=0)
    assert m.trimmed_mean == pytest.approx(1.0)
    assert m.mean > 10.0


def test_measure_calibrated_warmup_absorbs_slow_first_call():
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.05)       # stands in for jit compilation

    m = measure(fn, iters=3)       # warmup=None -> calibrated
    # the slow first call cannot be a timed sample: warmup ran past it
    assert m.warmup >= 2
    assert m.p50 < 0.05
    # fixed warmup is honored exactly
    calls["n"] = 0
    m2 = measure(fn, warmup=3, iters=2)
    assert m2.warmup == 3 and m2.count == 2


def test_measure_rejects_zero_iters():
    with pytest.raises(ValueError):
        measure(lambda: None, iters=0)


# ----------------------------------------------------------- profile_plan

@pytest.fixture(scope="module")
def profiled_plan():
    from repro.core.advisor import plan_for
    from repro.graphs.csr import random_power_law

    g = random_power_law(300, 5.0, seed=0)
    return plan_for(g, in_dim=16, hidden_dim=16, tune_iters=2,
                    with_backward=True)


def test_profile_plan_attribution_sums_to_total(profiled_plan):
    reg = MetricsRegistry()
    rep = profile_plan(profiled_plan, dim=16, iters=5, registry=reg)
    names = [s.schedule for s in rep.schedules]
    assert names == ["forward", "backward"]
    att = rep.attribution()
    assert set(att) == {"forward", "backward"}
    assert all(v > 0 for v in att.values())
    # the total runs the same jitted callables back to back, so the
    # per-schedule sum matches it up to CPU timing noise
    assert rep.attribution_error() < 0.5
    # registry side-effects: residual gauges labelled per schedule
    snap = {(m["name"], m["labels"].get("schedule")): m
            for m in reg.snapshot()}
    for sched in ("forward", "backward"):
        assert ("kernel_model_residual", sched) in snap
        assert snap[("kernel_model_residual", sched)]["value"] > 0
        assert ("profile_achieved_bytes_per_s", sched) in snap
    hist = [m for m in reg.snapshot()
            if m["name"] == "profile_schedule_seconds"]
    assert len(hist) == 2 and all(h["count"] == 5 for h in hist)


def test_profile_plan_shard_rows_excluded_from_attribution(profiled_plan):
    rep = profile_plan(profiled_plan, dim=16, iters=3, shards=2)
    names = [s.schedule for s in rep.schedules]
    assert "shard0/forward" in names and "shard1/forward" in names
    assert set(rep.attribution()) == {"forward", "backward"}
    rows = rep.to_rows()
    assert len(rows) == 4
    for r in rows:
        assert r["residual"] > 0 and r["p50_us"] > 0


def test_profile_plan_label_prefix(profiled_plan):
    rep = profile_plan(profiled_plan, dim=16, iters=2, label="b64/")
    assert [s.schedule for s in rep.schedules] == ["b64/forward",
                                                  "b64/backward"]


# ----------------------------------------------------------- chrome trace

def test_chrome_trace_doc_nesting_and_metadata():
    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            time.sleep(0.001)
    doc = chrome_trace_doc(tr, context={"git_sha": "abc"})
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    by_name = {e["name"]: e for e in evs}
    assert set(by_name) == {"outer", "outer/inner"}
    outer, inner = by_name["outer"], by_name["outer/inner"]
    # Perfetto nests by time containment: inner inside [outer, outer+dur]
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"] == {"k": 1}
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)
    assert any(e["name"] == "thread_name" for e in meta)
    assert doc["otherData"]["git_sha"] == "abc"


def test_write_chrome_trace_round_trip(tmp_path):
    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    with tr.span("a"):
        pass
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), tr)
    doc = json.load(open(path))
    assert any(e["name"] == "a" for e in doc["traceEvents"])
    assert doc["displayTimeUnit"] == "ms"


def test_trainer_emits_nested_train_spans(tmp_path):
    from repro.runtime.trainer import Trainer, TrainerConfig

    reg = MetricsRegistry()
    tr = SpanTracer(reg)
    trainer = Trainer(
        TrainerConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                      log_every=100),
        lambda state, batch: (state + 1, {"loss": float(state)}),
        lambda step: step, 0, tracer=tr)
    trainer.run(4)
    trainer.close()
    paths = {r["span"] for r in tr.records()}
    assert "train" in paths
    assert "train/step" in paths
    assert "train/step/batch" in paths
    assert "train/checkpoint" in paths        # ckpt_every=2, 4 steps
    # the same structure survives the Chrome-trace export
    doc = chrome_trace_doc(tr)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert any(n.startswith("train/") for n in names)


# -------------------------------------------------------------- baselines

def _rows(us, spread=0.05):
    return [{"name": "agg/x/group", "us_per_call": us,
             "p50_us": us, "p90_us": us * (1 + spread)}]


def test_baseline_make_validate_round_trip(tmp_path):
    doc = make_baseline("bench_x", _rows(100.0),
                        context={"git_sha": "abc"})
    assert validate_baseline(doc) == []
    path = tmp_path / "bench_x.json"
    save_baseline(doc, str(path))
    assert validate_baseline(json.load(open(path))) == []


@pytest.mark.parametrize("mutate, frag", [
    (lambda d: d.update(schema="nope"), "schema"),
    (lambda d: d.update(rows=[]), "rows"),
    (lambda d: d["rows"][0].pop("us_per_call"), "us_per_call"),
    (lambda d: d["rows"][0].pop("name"), "name"),
    (lambda d: d.update(history="not-a-list"), "history"),
])
def test_baseline_validate_negatives(mutate, frag):
    doc = make_baseline("bench_x", _rows(100.0),
                        context={"git_sha": "abc"})
    mutate(doc)
    problems = validate_baseline(doc)
    assert problems and any(frag in p for p in problems)


def test_row_tolerance_noise_aware():
    # no recorded spread -> generous fallback
    assert row_tolerance({"us_per_call": 10.0}) == pytest.approx(0.25)
    # recorded 5% spread, noise_factor 3 -> 15%
    b = _rows(100.0, spread=0.05)[0]
    assert row_tolerance(b) == pytest.approx(0.15)
    # the floor wins over a tiny spread
    tight = _rows(100.0, spread=0.01)[0]
    assert row_tolerance(tight, rel_floor=0.10) == pytest.approx(0.10)
    # the larger (noisier) of base/current governs
    noisy_cur = _rows(100.0, spread=0.20)[0]
    assert row_tolerance(b, noisy_cur) == pytest.approx(0.60)


def test_compare_rows_verdicts():
    base = _rows(100.0) + [{"name": "gone", "us_per_call": 5.0}]
    cur = _rows(100.0) + [{"name": "fresh", "us_per_call": 1.0}]
    v = {r["name"]: r["verdict"] for r in compare_rows(base, cur)}
    assert v == {"agg/x/group": "flat", "gone": "missing", "fresh": "new"}
    # 2x slower on a 15% tolerance -> regress; 2x faster -> improve
    slow = [{**_rows(200.0)[0]}]
    fast = [{**_rows(50.0)[0]}]
    assert compare_rows(_rows(100.0), slow)[0]["verdict"] == "regress"
    assert compare_rows(_rows(100.0), fast)[0]["verdict"] == "improve"


def test_compare_rows_spread_widens_tolerance():
    # +40% would regress on the default tolerance, but a recorded 20%
    # spread (x3 noise factor = 60% tolerance) absorbs it
    base, cur = _rows(100.0, spread=0.20), _rows(140.0, spread=0.20)
    assert compare_rows(base, cur)[0]["verdict"] == "flat"
    assert compare_rows(_rows(100.0), _rows(140.0))[0]["verdict"] == \
        "regress"


def test_append_history_bounded():
    from repro.obs import append_history
    doc = make_baseline("s", _rows(1.0))
    for i in range(60):
        append_history(doc, _rows(float(i + 1)),
                       context={"git_sha": f"sha{i}"}, max_history=50)
    assert len(doc["history"]) == 50
    assert doc["history"][-1]["git_sha"] == "sha59"
    assert doc["rows"][0]["us_per_call"] == 60.0


# -------------------------------------------------- bench_compare CLI gate

def _bench_doc(rows, ok=True):
    return {"schema": "repro.bench/v1", "section": "t", "module": "m",
            "ok": ok, "wall_s": 1.0, "context": {"git_sha": "abc"},
            "rows": rows}


def _write_pair(tmp_path, base_rows, cur_rows, section="bench_t"):
    bench_dir = tmp_path / "bench"
    base_dir = tmp_path / "baselines"
    bench_dir.mkdir(exist_ok=True)
    base_dir.mkdir(exist_ok=True)
    with open(bench_dir / f"BENCH_{section}.json", "w") as f:
        json.dump(_bench_doc(cur_rows), f)
    doc = make_baseline(section, base_rows, context={"git_sha": "abc"})
    save_baseline(doc, str(base_dir / f"{section}.json"))
    return str(bench_dir), str(base_dir)


def test_bench_compare_clean_exit_zero(tmp_path, capsys):
    bc = _load_bench_compare()
    bench_dir, base_dir = _write_pair(tmp_path, _rows(100.0), _rows(102.0))
    rc = bc.main(["--bench-dir", bench_dir, "--baseline-dir", base_dir])
    out = capsys.readouterr().out
    assert rc == 0
    assert "flat" in out and "agg/x/group" in out


def test_bench_compare_regression_exits_nonzero_naming_metric(tmp_path,
                                                              capsys):
    bc = _load_bench_compare()
    # synthetically slowed row: 3x the baseline, far past any tolerance
    bench_dir, base_dir = _write_pair(tmp_path, _rows(100.0), _rows(300.0))
    rc = bc.main(["--bench-dir", bench_dir, "--baseline-dir", base_dir])
    out = capsys.readouterr().out
    assert rc == 1
    assert "regress" in out and "agg/x/group" in out
    # --warn-only downgrades the perf failure, not the report
    rc = bc.main(["--bench-dir", bench_dir, "--baseline-dir", base_dir,
                  "--warn-only"])
    assert rc == 0
    assert "regress" in capsys.readouterr().out


def test_bench_compare_missing_row_fails(tmp_path, capsys):
    bc = _load_bench_compare()
    base = _rows(100.0) + [{"name": "dropped", "us_per_call": 5.0}]
    bench_dir, base_dir = _write_pair(tmp_path, base, _rows(100.0))
    rc = bc.main(["--bench-dir", bench_dir, "--baseline-dir", base_dir])
    out = capsys.readouterr().out
    assert rc == 1 and "MISSING" in out and "dropped" in out


def test_bench_compare_schema_mismatch_exits_two(tmp_path, capsys):
    bc = _load_bench_compare()
    bench_dir, base_dir = _write_pair(tmp_path, _rows(100.0), _rows(100.0))
    # corrupt the baseline schema: hard failure even under --warn-only
    bad = json.load(open(os.path.join(base_dir, "bench_t.json")))
    bad["schema"] = "wrong/v0"
    with open(os.path.join(base_dir, "bench_t.json"), "w") as f:
        json.dump(bad, f)
    rc = bc.main(["--bench-dir", bench_dir, "--baseline-dir", base_dir,
                  "--warn-only"])
    out = capsys.readouterr().out
    assert rc == 2 and "SCHEMA PROBLEM" in out


def test_bench_compare_failed_section_exits_two(tmp_path, capsys):
    bc = _load_bench_compare()
    bench_dir = tmp_path / "bench"
    bench_dir.mkdir()
    with open(bench_dir / "BENCH_t.json", "w") as f:
        json.dump(_bench_doc(_rows(1.0), ok=False), f)
    rc = bc.main(["--bench-dir", str(bench_dir),
                  "--baseline-dir", str(tmp_path / "baselines")])
    assert rc == 2
    assert "ok: false" in capsys.readouterr().out


def test_bench_compare_update_baselines(tmp_path, capsys):
    bc = _load_bench_compare()
    bench_dir = tmp_path / "bench"
    base_dir = tmp_path / "baselines"
    bench_dir.mkdir()
    with open(bench_dir / "BENCH_new.json", "w") as f:
        json.dump(_bench_doc(_rows(100.0)), f)
    # first run seeds the baseline ...
    rc = bc.main(["--bench-dir", str(bench_dir), "--baseline-dir",
                  str(base_dir), "--update-baselines"])
    assert rc == 0
    doc = json.load(open(base_dir / "new.json"))
    assert validate_baseline(doc) == [] and len(doc["history"]) == 1
    # ... a later update installs new rows and appends history, and a
    # would-be regression does not fail an update run
    with open(bench_dir / "BENCH_new.json", "w") as f:
        json.dump(_bench_doc(_rows(500.0)), f)
    rc = bc.main(["--bench-dir", str(bench_dir), "--baseline-dir",
                  str(base_dir), "--update-baselines"])
    assert rc == 0
    doc = json.load(open(base_dir / "new.json"))
    assert doc["rows"][0]["us_per_call"] == 500.0
    assert len(doc["history"]) == 2


def test_committed_baselines_are_valid():
    """The baselines shipped in-repo must satisfy their own schema."""
    base_dir = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                            "baselines")
    files = [f for f in os.listdir(base_dir) if f.endswith(".json")]
    assert files, "no committed baselines found"
    for f in files:
        doc = json.load(open(os.path.join(base_dir, f)))
        assert validate_baseline(doc, f) == []
